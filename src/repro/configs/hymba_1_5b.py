"""hymba-1.5b [hybrid] — parallel attention + mamba heads, sliding-window
attention (meta tokens omitted; see DESIGN.md). [arXiv:2411.13676; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_head_dim=64, sliding_window=1024,
)
