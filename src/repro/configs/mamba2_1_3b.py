"""mamba2-1.3b [ssm] — SSD, attention-free. [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)
