"""Assigned-architecture configs (--arch <id> selectable)."""
from .base import ModelConfig
from .granite_20b import CONFIG as granite_20b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .hymba_1_5b import CONFIG as hymba_1_5b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .mamba2_1_3b import CONFIG as mamba2_1_3b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .qwen1_5_0_5b import CONFIG as qwen1_5_0_5b
from .qwen2_5_14b import CONFIG as qwen2_5_14b
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .whisper_base import CONFIG as whisper_base

ARCHITECTURES = {
    c.name: c
    for c in [
        llama4_scout_17b_a16e, granite_moe_3b_a800m, qwen1_5_0_5b,
        mistral_large_123b, granite_20b, qwen2_5_14b, mamba2_1_3b,
        qwen2_vl_2b, whisper_base, hymba_1_5b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


# Input-shape cells assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    import dataclasses

    small = dict(
        num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128, vocab_size=256, head_dim=16,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_layers else 1500,
        num_patches=4 if cfg.family == "vlm" else cfg.num_patches,
        sliding_window=8 if cfg.sliding_window else 0,
        mrope_sections=(2, 3, 3) if cfg.mrope else cfg.mrope_sections,
        dtype="float32", remat="none", q_chunk=16, kv_chunk=16,
        moe_impl="dense",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
