"""Layer library: norms, rotary embeddings (RoPE / M-RoPE / sinusoidal),
GQA attention (online-softmax chunked for long sequences, cache decode,
sliding window, cross attention), SwiGLU/GELU MLPs, and MoE (dense smoke
mode + capacity-based scatter dispatch for expert parallelism at scale).

Everything is written against *global* logical shapes — pjit/GSPMD handles
partitioning; sharding constraints live in repro.distributed.sharding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .module import Creator, Params

NEG_INF = -1e30


# ------------------------------------------------------------------- norms
def rmsnorm_params(c: Creator, d: int) -> Params:
    return {"gamma": c.param((d,), "ones", dtype=jnp.float32)}


def rmsnorm(p: Params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * p["gamma"]).astype(x.dtype)


def layernorm_params(c: Creator, d: int) -> Params:
    return {
        "gamma": c.param((d,), "ones", dtype=jnp.float32),
        "beta": c.param((d,), "zeros", dtype=jnp.float32),
    }


def layernorm(p: Params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * p["gamma"] + p["beta"]).astype(
        x.dtype
    )


# ------------------------------------------------------------------ linear
def linear_params(c: Creator, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": c.param((d_in, d_out), "fan_in")}
    if bias:
        p["b"] = c.param((d_out,), "zeros", dtype=jnp.float32)
    return p


def linear(p: Params, x):
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = (y.astype(jnp.float32) + p["b"]).astype(y.dtype)
    return y


# ----------------------------------------------------------------- rotary
def rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    ang = ang[..., None, :]                                       # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def mrope(x, positions3, sections: Tuple[int, int, int], theta: float = 1e4):
    """Qwen2-VL multimodal RoPE.  positions3: (3, ..., S) for (t, h, w);
    frequency slots are split into three sections, each rotated by its own
    positional stream."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                            # (half,)
    # pick the positional stream per frequency slot via a one-hot mix
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)        # (half, 3)
    pos_t = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_mix = jnp.einsum("...k,hk->...h", pos_t, onehot)         # (..., S, half)
    ang = (pos_mix * freqs)[..., None, :]                        # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(S: int, d: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.float32) + offset
    inv = 1e4 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, d)


# -------------------------------------------------------------- attention
def attention_params(c: Creator, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": linear_params(c, d, cfg.num_heads * hd, cfg.qkv_bias),
        "wk": linear_params(c, d, cfg.num_kv_heads * hd, cfg.qkv_bias),
        "wv": linear_params(c, d, cfg.num_kv_heads * hd, cfg.qkv_bias),
        "wo": linear_params(c, cfg.num_heads * hd, d, False),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def online_attention(
    q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
    sliding_window: int = 0, q_offset: int = 0,
):
    """Online-softmax (flash-style) attention in pure jnp + lax.scan.

    q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd).  GSPMD-shardable; never
    materializes the full (Sq, Sk) score matrix — required for the 32k
    prefill cells.  This is the jnp twin of kernels/stitched_attention.py
    (the Pallas kernel is the single-device TPU fast path).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    cq = min(q_chunk, Sq)
    ck = min(kv_chunk, Sk)
    while Sq % cq:
        cq -= 1
    while Sk % ck:
        ck -= 1
    nq, nk = Sq // cq, Sk // ck
    # GQA WITHOUT jnp.repeat: a grouped einsum over (kv-head, group) keeps
    # K/V unexpanded — MQA (G=H) would otherwise replicate the cache H×.
    q_ = q.reshape(B, nq, cq, Hkv, G, hd)
    k_ = k.reshape(B, nk, ck, Hkv, hd)
    v_ = v.reshape(B, nk, ck, Hkv, hd)
    out_dtype = q.dtype

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_step_inner(iq):
        # rematerialized in backward: without this, autodiff through the
        # nested scans stashes EVERY (cq, ck) probability chunk — the full
        # score matrix — defeating the online-softmax memory savings.
        qc = q_[:, iq].astype(jnp.float32) * scale   # (B, cq, Hkv, G, hd)

        def kv_step(carry, ik):
            m, denom, acc = carry
            kc = k_[:, ik].astype(jnp.float32)       # (B, ck, Hkv, hd)
            vc = v_[:, ik].astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc)
            qpos = q_offset + iq * cq + jnp.arange(cq)
            kpos = ik * ck + jnp.arange(ck)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if sliding_window:
                mask &= qpos[:, None] - kpos[None, :] < sliding_window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            denom_new = denom * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vc
            )
            return (m_new, denom_new, acc_new), None

        init = (
            jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, cq), jnp.float32),
            jnp.zeros((B, Hkv, G, cq, hd), jnp.float32),
        )
        (m, denom, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / denom[..., None]                      # (B, Hkv, G, cq, hd)
        # cast BEFORE the outer scan stacks chunks (f32 stacking doubles the
        # activation output footprint at 32k sequence lengths)
        return out.transpose(0, 3, 1, 2, 4).astype(out_dtype)  # (B,cq,Hkv,G,hd)

    def q_step(_, iq):
        return None, q_step_inner(iq)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq,B,cq,Hkv,G,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)
    return out


def decode_attention_jnp(q, k_cache, v_cache, length,
                         k_scale=None, v_scale=None):
    """q: (B, H, hd) one token; caches (B, S, Hkv, hd); length () or (B,).

    Grouped einsum (no KV expansion).  The hd contraction is sharded over
    'model' (cache head_dim sharding) — GSPMD inserts one small psum for the
    scores; softmax is then local over the full cache length.

    With ``k_scale/v_scale`` (B, S, Hkv) the caches are int8 and the scales
    fold into the scores/weights AFTER the int8 reads — HBM traffic is the
    int8 payload (the decode memory-roofline lever).
    """
    B, H, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * scale
    # pin q's head_dim to the cache's 'model' sharding so GSPMD contracts
    # the sharded hd (one tiny psum on the scores) instead of resharding
    # the WHOLE cache to head-sharded every step (§Perf iteration A3; the
    # "involuntary full rematerialization" copy in the SPMD log)
    qg = _constrain_last_dim_model(qg)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kc)          # (B, Hkv, G, S)
    if k_scale is not None:
        s = s * jnp.transpose(k_scale, (0, 2, 1))[:, :, None, :]
    valid = jnp.arange(S)[None, None, None, :] < jnp.reshape(length, (-1, 1, 1, 1))
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    if v_scale is not None:
        p = p * jnp.transpose(v_scale, (0, 2, 1))[:, :, None, :]
    out = jnp.einsum("bhgk,bkhd->bhgd", p, vc) / jnp.sum(
        jnp.exp(s - m), axis=-1
    )[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)


def _constrain_last_dim_model(x):
    """Shard the last dim over 'model' when a mesh is active and divides."""
    from ..distributed.sharding import current_mesh

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[-1] % mesh.shape["model"]:
        return x
    try:
        from jax.sharding import PartitionSpec as P

        spec = [None] * (x.ndim - 1) + ["model"]
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def quantize_kv_int8(x):
    """x: (B, Hkv, hd) -> (int8 values, (B, Hkv) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    s = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


# ------------------------------------------------------------------- MLPs
def swiglu_params(c: Creator, d: int, ff: int) -> Params:
    return {
        "wi": linear_params(c, d, ff),
        "wg": linear_params(c, d, ff),
        "wo": linear_params(c, ff, d),
    }


def swiglu(p: Params, x):
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))


def gelu_mlp_params(c: Creator, d: int, ff: int) -> Params:
    return {
        "wi": linear_params(c, d, ff, bias=True),
        "wo": linear_params(c, ff, d, bias=True),
    }


def gelu_mlp(p: Params, x):
    return linear(p["wo"], jax.nn.gelu(linear(p["wi"], x)))


def _constrain_rows_model(x):
    """Shard a (rows, d) expert-dispatch buffer's rows over 'model' (EP):
    keeps the scatter/gather path from replicating the whole dispatch
    tensor per device.  No-op outside a mesh context."""
    from ..distributed.sharding import current_mesh

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[0] % mesh.shape["model"]:
        return x
    try:
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P("model", None))
    except (ValueError, RuntimeError):
        return x


# -------------------------------------------------------------------- MoE
def moe_params(c: Creator, cfg) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    Ep = E + cfg.moe_pad_experts      # dummy experts receive no tokens
    return {
        "router": c.param((d, E), "fan_in", dtype=jnp.float32),
        "wi": c.param((Ep, d, ff), "fan_in"),
        "wg": c.param((Ep, d, ff), "fan_in"),
        "wo": c.param((Ep, ff, d), "fan_in"),
    }


def _router(p: Params, x, cfg):
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx                                      # (..., k)


def moe_dense(p: Params, x, cfg):
    """Smoke-test mode: every expert computes every token, masked combine.
    Exact (no capacity drops); O(E) compute — tiny configs only."""
    w, idx = _router(p, x, cfg)                        # (B, S, k)
    h = jnp.einsum("bsd,edf->bsef", x, p["wg"])
    hi = jnp.einsum("bsd,edf->bsef", x, p["wi"])
    y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * hi, p["wo"])
    onehot = jax.nn.one_hot(
        idx, cfg.moe_experts + cfg.moe_pad_experts, dtype=jnp.float32
    )  # (B,S,k,Ep)
    mix = jnp.einsum("bske,bsk->bse", onehot, w)
    return jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), mix).astype(x.dtype)


def moe_scatter(p: Params, x, cfg):
    """GROUP-WISE capacity dispatch (EP at scale): each sequence is its own
    GShard group — routing positions, the (E, C_g, d) expert batches, and
    the combine are all computed per group via vmap, so every dispatch
    tensor carries the BATCH dim and shards over (pod, data).  (A global
    dispatch's capacity tensor scales with ALL tokens and replicates — the
    51 GiB MoE-prefill blow-up in EXPERIMENTS §Dry-run.)  Over-capacity
    tokens within a group drop (standard GShard semantics).
    """
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    Ep = E + cfg.moe_pad_experts
    w, idx = _router(p, x, cfg)                        # (B, S, k)
    C = int(np.ceil(cfg.moe_capacity_factor * k * S / E))
    C = max(64, (C + 63) // 64 * 64)

    def per_group(xg, wg_, idxg):
        """xg (S, d); idxg (S, k) -> (S·k routing within this group)."""
        flat_e = idxg.reshape(-1)                      # (S*k,)
        # int16 routing cumsum (§Perf B6): C < 32768 at any group size
        pos_dt = jnp.int16 if C < 32767 else jnp.int32
        onehot = jax.nn.one_hot(flat_e, E, dtype=pos_dt)      # (S*k, E)
        pos = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)
        keep = slot < C
        token_of = jnp.repeat(jnp.arange(S), k)
        flat_slot = jnp.where(keep, flat_e * C + slot, Ep * C)
        gathered = jnp.zeros((Ep * C + 64, d), xg.dtype).at[flat_slot].set(
            xg[token_of]
        )
        return gathered[: Ep * C].reshape(Ep, C, d), flat_slot, keep

    ein, flat_slot, keep = jax.vmap(per_group)(x, w, idx)     # (B,Ep,C,d)
    h = jnp.einsum("gecd,edf->gecf", ein, p["wg"])
    hi = jnp.einsum("gecd,edf->gecf", ein, p["wi"])
    out_e = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * hi, p["wo"])

    def combine(rows_g, slot_g, keep_g, w_g):
        rows = rows_g.reshape(Ep * C, d)
        # bf16 combine (§Perf B4): f32 accumulate on the MXU only
        picked = jnp.where(
            keep_g[:, None], rows[jnp.minimum(slot_g, Ep * C - 1)],
            jnp.zeros((), rows.dtype),
        )                                              # (S*k, d)
        return jnp.einsum(
            "skd,sk->sd", picked.reshape(S, k, d), w_g.astype(picked.dtype),
            preferred_element_type=jnp.float32,
        )

    yt = jax.vmap(combine)(out_e, flat_slot, keep, w)  # (B, S, d)
    return yt.astype(x.dtype)


def moe(p: Params, x, cfg):
    if cfg.moe_impl == "dense":
        return moe_dense(p, x, cfg)
    return moe_scatter(p, x, cfg)


# -------------------------------------------------------------- embedding
def embedding_params(c: Creator, cfg) -> Params:
    return {
        "tok": c.param((cfg.padded_vocab, cfg.d_model), "normal"),
        "unembed": c.param((cfg.d_model, cfg.padded_vocab), "fan_in"),
    }


def embed(p: Params, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Params, x):
    return jnp.einsum("...d,dv->...v", x, p["unembed"])
