"""Model zoo: unified transformer covering dense / MoE / SSM / hybrid /
VLM-backbone / audio-enc-dec families."""
from .module import Creator, count_params, tree_bytes
from .transformer import (
    decode_chunk,
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    param_specs,
    prefill_cross_attention,
)
