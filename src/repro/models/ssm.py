"""Mamba2 — SSD (state-space duality) block, arXiv:2405.21060.

Training/prefill uses the chunked dual form (quadratic attention-like
intra-chunk einsums + linear inter-chunk recurrence — all MXU-friendly);
decode is the O(1)-per-token recurrent state update, which is what makes the
``long_500k`` cell tractable for the SSM/hybrid architectures.

ngroups=1 (B/C shared across heads), depthwise causal conv width 4 on
(x, B, C), gated RMSNorm output — the standard minimal-Mamba2 structure.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import linear, linear_params, rmsnorm
from .module import Creator, Params


def ssm_dims(cfg) -> Tuple[int, int, int, int]:
    """(d_inner, num_heads, head_dim P, state N)."""
    if cfg.family == "hybrid":
        d_in = cfg.num_heads * cfg.ssm_head_dim        # parallel-head width
    else:
        d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    return d_in, H, P, cfg.ssm_state


def mamba2_params(c: Creator, cfg) -> Params:
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "in_proj": linear_params(c, d, 2 * d_in + 2 * N + H),
        "conv_w": c.param((cfg.ssm_conv_width, conv_ch), "normal", scale=0.1),
        "conv_b": c.param((conv_ch,), "zeros", dtype=jnp.float32),
        "A_log": c.param((H,), "zeros", dtype=jnp.float32),
        "D": c.param((H,), "ones", dtype=jnp.float32),
        "dt_bias": c.param((H,), "zeros", dtype=jnp.float32),
        "norm": {"gamma": c.param((d_in,), "ones", dtype=jnp.float32)},
        "out_proj": linear_params(c, d_in, d),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, C), w (K, C)."""
    K = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pads[:, i: i + x.shape[1]].astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype)


def _segsum(dA):
    """dA: (..., L, H) -> cumulative decay matrix T[i, j] = sum_{j<k<=i} dA_k
    (lower-triangular; -inf above the diagonal)."""
    L = dA.shape[-2]
    cs = jnp.cumsum(dA, axis=-2)                               # (..., L, H)
    diff = cs[..., :, None, :] - cs[..., None, :, :]           # (..., L, L, H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask[..., None], diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk: int):
    """The SSD dual-form scan.

    x  : (B, S, H, P)   dt : (B, S, H)  (post-softplus)
    Bm : (B, S, N)      Cm : (B, S, N)
    returns y (B, S, H, P) and final state (B, H, P, N).
    """
    b, S, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    A = -jnp.exp(A_log.astype(jnp.float32))                    # (H,)
    dA = dt * A                                                # (B, S, H)
    xc = x.reshape(b, nc, c, H, P).astype(jnp.float32)
    dtc = dt.reshape(b, nc, c, H)
    dAc = dA.reshape(b, nc, c, H)
    Bc = Bm.reshape(b, nc, c, N).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, c, N).astype(jnp.float32)

    # intra-chunk (quadratic within chunk, like masked attention)
    Lmat = jnp.exp(_segsum(dAc))                               # (b,nc,c,c,H)
    scores = jnp.einsum("bzln,bzsn->bzls", Cc, Bc)             # (b,nc,c,c)
    M = scores[..., None] * Lmat                               # (b,nc,l,s,H)
    y_diag = jnp.einsum("bzlsh,bzsh,bzshp->bzlhp", M, dtc, xc)

    # chunk-final states
    cs = jnp.cumsum(dAc, axis=2)                               # (b,nc,c,H)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)              # (b,nc,c,H)
    states = jnp.einsum("bzsn,bzsh,bzshp->bzhpn", Bc, decay_to_end * dtc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, -1, :])                     # (b,nc,H)

    def step(s_prev, inp):
        st, dec = inp
        s_new = st + dec[..., None, None] * s_prev
        return s_new, s_prev

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    final, prevs = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prevs, 0, 1)                    # (b,nc,H,P,N)

    decay_from_start = jnp.exp(cs)                             # (b,nc,c,H)
    y_off = jnp.einsum(
        "bzln,bzhpn,bzlh->bzlhp", Cc, prev_states, decay_from_start
    )
    y = (y_diag + y_off).reshape(b, S, H, P)
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final


def mamba2_forward(p: Params, x, cfg, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d).  Full-sequence (train / prefill)."""
    B, S, d = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    zxbcdt = linear(p["in_proj"], x)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    )
    xs, Bm, Cm = (
        conv_out[..., :d_in],
        conv_out[..., d_in: d_in + N],
        conv_out[..., d_in + N:],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(B, S, H, P)
    y, state = ssd_chunked(xh, dt, p["A_log"], Bm, Cm, p["D"], chunk=128)
    y = y.reshape(B, S, d_in)
    y = rmsnorm(p["norm"], (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), cfg.norm_eps)
    out = linear(p["out_proj"], y)
    if return_state:
        conv_tail = conv_in[:, -(cfg.ssm_conv_width - 1):, :]
        return out, {"ssm": state, "conv": conv_tail}
    return out


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32) -> Dict:
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def mamba2_decode_step(p: Params, x, cache: Dict, cfg, active=None):
    """x: (B, d) one token; O(1) state update (the long_500k path).

    ``active``: optional (B,) bool — inactive rows keep their old state
    (continuous-batching write mask)."""
    B, d = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    zxbcdt = linear(p["in_proj"], x)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)           # (B, C)
    window = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), p["conv_w"]
    ) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_in]
    Bm = conv_out[..., d_in: d_in + N]
    Cm = conv_out[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B, H)
    xh = xs.reshape(B, H, P)
    state = cache["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + p["D"][None, :, None] * xh
    y = y.reshape(B, d_in)
    y = rmsnorm(
        p["norm"],
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
        cfg.norm_eps,
    )
    out = linear(p["out_proj"], y)
    new_conv = window[:, 1:, :].astype(cache["conv"].dtype)
    if active is not None:
        sel = active.reshape(B, *([1] * (state.ndim - 1)))
        state = jnp.where(sel, state, cache["ssm"])
        selc = active.reshape(B, *([1] * (new_conv.ndim - 1)))
        new_conv = jnp.where(selc, new_conv, cache["conv"])
    new_cache = {"ssm": state, "conv": new_conv}
    return out, new_cache
