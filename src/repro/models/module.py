"""Minimal functional module system (no flax): params are nested dicts of
arrays, built by a single structure-walker that can either materialize
(``init``) or produce ``jax.ShapeDtypeStruct`` stand-ins (``param_specs``)
for allocation-free multi-pod dry-runs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


class Creator:
    """Walks the parameter structure.  ``materialize=False`` yields
    ShapeDtypeStructs (dry-run); True yields initialized arrays."""

    def __init__(self, rng: Optional[jax.Array], dtype, materialize: bool):
        self._rng = rng
        self.dtype = jnp.dtype(dtype)
        self.materialize = materialize

    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def param(self, shape: Tuple[int, ...], init: str = "normal",
              scale: float = 0.02, dtype=None) -> Any:
        dtype = jnp.dtype(dtype) if dtype is not None else self.dtype
        if not self.materialize:
            return jax.ShapeDtypeStruct(tuple(shape), dtype)
        r = self._next_rng()
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            return (jax.random.normal(r, shape, jnp.float32) * scale).astype(dtype)
        if init == "fan_in":
            fan = shape[0] if len(shape) >= 2 else 1
            return (
                jax.random.normal(r, shape, jnp.float32) * (fan ** -0.5)
            ).astype(dtype)
        if init == "uniform_scalar":
            return jnp.full(shape, scale, dtype)
        raise ValueError(init)


def stack_layers(layer_fn: Callable[[Creator], Params], creator: Creator,
                 num_layers: int) -> Params:
    """Build ``num_layers`` copies of a layer's params stacked on axis 0 —
    the layout ``jax.lax.scan`` over layers consumes (keeps HLO size O(1) in
    depth, which keeps 512-device SPMD compiles tractable)."""
    one = layer_fn(creator)

    def _stack(leaf):
        if isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((num_layers,) + tuple(leaf.shape), leaf.dtype)
        return leaf  # placeholder; replaced below for materialized params

    if not creator.materialize:
        return jax.tree.map(_stack, one)
    links = [one] + [layer_fn(creator) for _ in range(num_layers - 1)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *links)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return int(
        sum(
            int(np.prod(leaf.shape))
            for leaf in leaves
            if hasattr(leaf, "shape")
        )
    )


def tree_bytes(tree) -> int:
    return int(
        sum(
            int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(tree)
            if hasattr(leaf, "shape")
        )
    )
