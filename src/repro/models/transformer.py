"""Unified model assembly for all assigned architecture families.

One decoder-LM skeleton with per-family layer bodies (dense / MoE / SSM /
hybrid / VLM backbone / whisper enc-dec), scan-over-layers with stacked
params (HLO size O(1) in depth — keeps 512-device SPMD compiles tractable),
configurable remat, full-sequence ``forward`` (train/prefill) and O(1)
``decode_step`` with KV / SSM-state / sliding-window-ring caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .module import Creator, Params, stack_layers


# ======================================================================
# parameter construction
# ======================================================================
def layer_params(c: Creator, cfg) -> Params:
    fam = cfg.family
    p: Params = {"ln1": L.rmsnorm_params(c, cfg.d_model)}
    if fam == "ssm":
        p["mamba"] = S.mamba2_params(c, cfg)
        return p
    if fam == "audio":  # whisper decoder layer (pre-LN layernorm, GELU mlp)
        return {
            "ln1": L.layernorm_params(c, cfg.d_model),
            "attn": L.attention_params(c, cfg),
            "lnx": L.layernorm_params(c, cfg.d_model),
            "xattn": L.attention_params(c, cfg),
            "ln2": L.layernorm_params(c, cfg.d_model),
            "mlp": L.gelu_mlp_params(c, cfg.d_model, cfg.d_ff),
        }
    p["attn"] = L.attention_params(c, cfg)
    if fam == "hybrid":
        p["mamba"] = S.mamba2_params(c, cfg)
        p["norm_a"] = L.rmsnorm_params(c, cfg.d_model)
        p["norm_m"] = L.rmsnorm_params(c, cfg.d_model)
    p["ln2"] = L.rmsnorm_params(c, cfg.d_model)
    if fam == "moe":
        p["moe"] = L.moe_params(c, cfg)
    else:
        p["mlp"] = L.swiglu_params(c, cfg.d_model, cfg.d_ff)
    return p


def encoder_layer_params(c: Creator, cfg) -> Params:
    return {
        "ln1": L.layernorm_params(c, cfg.d_model),
        "attn": L.attention_params(c, cfg),
        "ln2": L.layernorm_params(c, cfg.d_model),
        "mlp": L.gelu_mlp_params(c, cfg.d_model, cfg.d_ff),
    }


def model_params(cfg, rng: Optional[jax.Array] = None,
                 materialize: bool = True) -> Params:
    c = Creator(rng, cfg.jax_dtype, materialize)
    p: Params = {"embed": L.embedding_params(c, cfg)}
    p["layers"] = stack_layers(lambda cc: layer_params(cc, cfg), c, cfg.num_layers)
    if cfg.family == "audio":
        p["ln_f"] = L.layernorm_params(c, cfg.d_model)
        p["enc_layers"] = stack_layers(
            lambda cc: encoder_layer_params(cc, cfg), c, cfg.encoder_layers
        )
        p["enc_ln_f"] = L.layernorm_params(c, cfg.d_model)
    else:
        p["ln_f"] = L.rmsnorm_params(c, cfg.d_model)
    if cfg.family == "vlm":
        p["patch_proj"] = L.linear_params(c, cfg.d_model, cfg.d_model)
    return p


def param_specs(cfg) -> Params:
    return model_params(cfg, rng=None, materialize=False)


def init_params(cfg, seed: int = 0) -> Params:
    return model_params(cfg, rng=jax.random.PRNGKey(seed), materialize=True)


# ======================================================================
# full-sequence forward (train / prefill)
# ======================================================================
def _attn_full(p, x, cfg, positions, causal=True, kv_x=None, use_mrope=False,
               positions3=None):
    """x: (B, S, d) -> (B, S, d) attention with online softmax."""
    B, Sq, d = x.shape
    hd, H, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    src = x if kv_x is None else kv_x
    q = L._split_heads(L.linear(p["wq"], x), H, hd)
    k = L._split_heads(L.linear(p["wk"], src), Hkv, hd)
    v = L._split_heads(L.linear(p["wv"], src), Hkv, hd)
    if cfg.family != "audio":  # whisper uses additive sinusoidal positions
        if use_mrope:
            q = L.mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
            k = L.mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
        elif kv_x is None:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
    o = L.online_attention(
        q, k, v,
        causal=causal and kv_x is None,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        sliding_window=cfg.sliding_window if kv_x is None else 0,
    )
    return L.linear(p["wo"], o.reshape(B, Sq, H * hd))


def _layer_fwd(lp: Params, x, cfg, positions, positions3=None, enc_out=None):
    fam = cfg.family
    if fam == "ssm":
        return x + S.mamba2_forward(lp["mamba"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg)
    if fam == "audio":
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + _attn_full(lp["attn"], h, cfg, positions, causal=True)
        hx = L.layernorm(lp["lnx"], x, cfg.norm_eps)
        x = x + _attn_full(lp["xattn"], hx, cfg, positions, kv_x=enc_out)
        h2 = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        return x + L.gelu_mlp(lp["mlp"], h2)
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if fam == "hybrid":
        a = _ckpt_name(_attn_full(lp["attn"], h, cfg, positions), "attn_out", cfg)
        m = S.mamba2_forward(lp["mamba"], h, cfg)
        mix = (
            L.rmsnorm(lp["norm_a"], a, cfg.norm_eps).astype(jnp.float32)
            + L.rmsnorm(lp["norm_m"], m, cfg.norm_eps).astype(jnp.float32)
        ) * 0.5
        x = x + mix.astype(x.dtype)
    else:
        x = x + _ckpt_name(
            _attn_full(
                lp["attn"], h, cfg, positions,
                use_mrope=cfg.mrope, positions3=positions3,
            ),
            "attn_out", cfg,
        )
    h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if fam == "moe":
        return x + _ckpt_name(L.moe(lp["moe"], h2, cfg), "ffn_out", cfg)
    return x + _ckpt_name(L.swiglu(lp["mlp"], h2), "ffn_out", cfg)


def _ckpt_name(x, name: str, cfg=None):
    """Tag for selective remat — a no-op otherwise (the tag itself makes
    XLA materialize the boundary, +4.5 GiB on mistral under full remat)."""
    if cfg is None or cfg.remat != "selective":
        return x
    from jax.ad_checkpoint import checkpoint_name

    return checkpoint_name(x, name)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots
        )
    if cfg.remat == "selective":
        # save ONLY the per-layer attention/FFN outputs ((B,S,d)-shaped):
        # kills most recompute at 3x the carry stash — the middle ground
        # between "full" (useful≈0.73) and "dots" (HBM blow-up), §Perf C2
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out"
            ),
        )
    return jax.checkpoint(fn)


def _scan_layers(stacked: Params, x, body, cfg=None):
    sp = cfg is not None and cfg.activation_sharding == "sp"
    if sp:
        from ..distributed.sharding import constrain_sp

    def step(carry, lp):
        out = body(lp, carry)
        if sp:
            out = constrain_sp(out)   # shard the remat stash 'model'-ways
        return out, None

    if sp:
        x = constrain_sp(x)
    out, _ = jax.lax.scan(step, x, stacked)
    return out


def mrope_positions(cfg, B: int, S_total: int):
    """(3, B, S): patches get (0, h, w) on a sqrt grid; text gets (t, t, t)."""
    P = cfg.num_patches
    g = max(1, int(P ** 0.5))
    idx = jnp.arange(P)
    pt = jnp.zeros((P,), jnp.int32)
    ph = (idx // g).astype(jnp.int32)
    pw = (idx % g).astype(jnp.int32)
    t_text = jnp.arange(S_total - P, dtype=jnp.int32) + g
    three = jnp.stack(
        [
            jnp.concatenate([pt, t_text]),
            jnp.concatenate([ph, t_text]),
            jnp.concatenate([pw, t_text]),
        ]
    )                                                   # (3, S)
    return jnp.broadcast_to(three[:, None, :], (3, B, S_total))


def encode_audio(params: Params, frames, cfg):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    B, Se, d = frames.shape
    x = frames + L.sinusoidal_positions(Se, d).astype(frames.dtype)[None]

    def body(lp, h):
        z = L.layernorm(lp["ln1"], h, cfg.norm_eps)
        h = h + _attn_full(lp["attn"], z, cfg, None, causal=False)
        z2 = L.layernorm(lp["ln2"], h, cfg.norm_eps)
        return h + L.gelu_mlp(lp["mlp"], z2)

    x = _scan_layers(params["enc_layers"], x, _remat(body, cfg), cfg)
    return L.layernorm(params["enc_ln_f"], x, cfg.norm_eps)


def forward(params: Params, batch: Dict[str, Any], cfg,
            return_hidden: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B, S, padded_vocab) in f32, or the
    pre-unembed hidden states (B, S, d) when ``return_hidden`` (the chunked
    vocab-parallel loss path — avoids materializing all-position logits)."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions3 = None
    enc_out = None
    if cfg.family == "vlm":
        patches = L.linear(params["patch_proj"], batch["patches"]).astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        positions3 = mrope_positions(cfg, B, x.shape[1])
    if cfg.family == "audio":
        enc_out = encode_audio(params, batch["frames"], cfg)
        x = x + L.sinusoidal_positions(S_text, cfg.d_model).astype(x.dtype)[None]
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    body = _remat(
        functools.partial(
            _layer_fwd, cfg=cfg, positions=positions,
            positions3=positions3, enc_out=enc_out,
        ),
        cfg,
    )
    x = _scan_layers(params["layers"], x, lambda lp, h: body(lp, h), cfg)
    if cfg.family == "audio":
        x = L.layernorm(params["ln_f"], x, cfg.norm_eps)
    else:
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, -S_text:]                    # loss over text positions only
    if return_hidden:
        return x
    logits = L.unembed(params["embed"], x).astype(jnp.float32)
    return logits


# ======================================================================
# decode path (serving)
# ======================================================================
def init_cache(cfg, batch: int, max_len: int) -> Params:
    """Stacked (L, ...) cache pytree.  Sliding-window archs use a ring of
    size ``min(window, max_len)``; SSM keeps O(1) state."""
    Lh, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jax_dtype
    cache: Params = {}
    if cfg.family == "ssm":
        one = S.mamba2_init_cache(cfg, batch, dt)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.zeros((Lh,) + a.shape, a.dtype), one
        )
        return cache
    W = max_len if not cfg.sliding_window else min(cfg.sliding_window, max_len)
    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt
    # W ring slots + 1 parking slot for masked (inactive-row) writes
    cache["k"] = jnp.zeros((Lh, batch, W + 1, Hkv, hd), kv_dt)
    cache["v"] = jnp.zeros((Lh, batch, W + 1, Hkv, hd), kv_dt)
    if cfg.kv_cache_dtype == "int8":
        cache["k_scale"] = jnp.zeros((Lh, batch, W + 1, Hkv), jnp.float32)
        cache["v_scale"] = jnp.zeros((Lh, batch, W + 1, Hkv), jnp.float32)
    if cfg.family == "hybrid":
        one = S.mamba2_init_cache(cfg, batch, dt)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.zeros((Lh,) + a.shape, a.dtype), one
        )
    if cfg.family == "audio":
        cache["xk"] = jnp.zeros((Lh, batch, cfg.encoder_seq, Hkv, hd), dt)
        cache["xv"] = jnp.zeros((Lh, batch, cfg.encoder_seq, Hkv, hd), dt)
    return cache


def init_paged_cache(cfg, num_blocks: int, block_size: int,
                     decode_width: int) -> Params:
    """Paged KV cache: a single (L, num_blocks + 1, block_size, Hkv, hd)
    block pool SHARED by every request (physical block ``num_blocks`` is the
    parking block for masked writes), instead of per-slot contiguous rings.
    Rows own logical->physical block tables managed by the serving layer's
    ``BlockAllocator``; SSM/conv state stays per-row O(1) (it does not
    page), sized by ``decode_width`` — the decode batch width, now
    independent of KV memory reservation."""
    Lh, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jax_dtype
    if cfg.family == "audio":
        raise ValueError(
            "paged KV decode does not support the audio family (the "
            "cross-attention cache is per-row dense, not positional)"
        )
    cache: Params = {}
    if cfg.family != "ssm":
        kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt
        shape = (Lh, num_blocks + 1, block_size, Hkv, hd)
        cache["k"] = jnp.zeros(shape, kv_dt)
        cache["v"] = jnp.zeros(shape, kv_dt)
        if cfg.kv_cache_dtype == "int8":
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        one = S.mamba2_init_cache(cfg, decode_width, dt)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.zeros((Lh,) + a.shape, a.dtype), one
        )
    return cache


def _attn_decode(p, x, cache_l, pos, cfg, window: int, active=None,
                 keys=("k", "v"), block_table=None, kv_ring=None):
    """x: (B, d) one token; cache_l holds (B, W, Hkv, hd) ring caches
    (plus (B, W, Hkv) scale planes when the cache is int8-quantized).

    ``pos``: (B,) per-slot absolute positions (continuous batching);
    ``active``: optional (B,) bool write mask.

    With ``block_table`` (B, max_blocks) int32 the cache is PAGED instead:
    ``cache_l[k]`` is a shared (num_blocks + 1, block_size, Hkv, hd) block
    pool and each row reads/writes through its table (``kv_ring`` is the
    static logical ring capacity in tokens).  The gather happens here,
    inside the jitted decode — one launch per tick regardless of how
    requests map onto physical blocks."""
    kk, vk = keys
    kc, vc = cache_l[kk], cache_l[vk]
    B, d = x.shape
    hd, H, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = L.linear(p["wq"], x).reshape(B, H, hd)
    k = L.linear(p["wk"], x).reshape(B, Hkv, hd)
    v = L.linear(p["wv"], x).reshape(B, Hkv, hd)
    posb = pos.reshape(B, 1)
    if cfg.family != "audio":
        q = L.rope(q.reshape(B, 1, H, hd), posb, cfg.rope_theta).reshape(B, H, hd)
        k = L.rope(k.reshape(B, 1, Hkv, hd), posb, cfg.rope_theta).reshape(B, Hkv, hd)
    if block_table is not None:
        return _paged_kv_attend(
            p, cache_l, q, k, v, pos, cfg, active, keys, block_table, kv_ring
        )
    # cache layout: W ring slots + 1 PARKING slot (index W).  Inactive
    # batch rows write to the parking slot instead of a masked full-cache
    # jnp.where copy — the where materialized a whole-cache rewrite per
    # layer per step (§Perf iteration A1); the parking row is always beyond
    # ``length`` so attention never reads it.
    W = kc.shape[1] - 1
    slot = pos % W
    act = active if active is not None else jnp.ones((B,), bool)
    slot = jnp.where(act, slot, W)
    quant = cfg.kv_cache_dtype == "int8" and kk == "k"

    def upd(c, xnew, s):
        return jax.lax.dynamic_update_slice(
            c, xnew[None], (s,) + (0,) * (c.ndim - 1)
        )

    updates = {}
    if quant:
        k8, ks = L.quantize_kv_int8(k)
        v8, vs = L.quantize_kv_int8(v)
        kc = jax.vmap(upd)(kc, k8, slot)
        vc = jax.vmap(upd)(vc, v8, slot)
        ksc = jax.vmap(upd)(cache_l["k_scale"], ks, slot)
        vsc = jax.vmap(upd)(cache_l["v_scale"], vs, slot)
        updates.update(k_scale=ksc, v_scale=vsc)
        k_scale_r, v_scale_r = ksc, vsc
    else:
        kc = jax.vmap(upd)(kc, k, slot)
        vc = jax.vmap(upd)(vc, v, slot)
        k_scale_r = v_scale_r = None
    updates[kk] = kc
    updates[vk] = vc
    length = jnp.minimum(pos + 1, W)
    o = L.decode_attention_jnp(q, kc, vc, length, k_scale_r, v_scale_r)
    return L.linear(p["wo"], o.reshape(B, H * hd)), updates


def _paged_kv_attend(p, cache_l, q, k, v, pos, cfg, active, keys,
                     block_table, kv_ring: int):
    """Paged read/write for one decode step.

    The pool keeps ``num_blocks`` real blocks + 1 PARKING block (physical
    index ``num_blocks``): inactive rows scatter their write there (never
    read — the same masked-write idiom as the contiguous ring's parking
    slot), and unassigned table entries point there so the gather below is
    always in-bounds.  Ring arithmetic (``pos % kv_ring``) reuses blocks
    cyclically for sliding-window architectures; attention is permutation-
    invariant over the key axis (RoPE is applied at write time), so ring
    order needs no unscrambling."""
    kk, vk = keys
    kc, vc = cache_l[kk], cache_l[vk]          # (NB+1, bs, Hkv, hd)
    B = q.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    parking = kc.shape[0] - 1
    bs = kc.shape[1]
    nblk = block_table.shape[1]
    off_tot = pos % kv_ring
    blk = off_tot // bs
    off = off_tot % bs
    phys = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    act = active if active is not None else jnp.ones((B,), bool)
    phys = jnp.where(act, phys, parking)
    quant = cfg.kv_cache_dtype == "int8" and kk == "k"
    updates = {}
    if quant:
        k8, ks = L.quantize_kv_int8(k)
        v8, vs = L.quantize_kv_int8(v)
        kc = kc.at[phys, off].set(k8)
        vc = vc.at[phys, off].set(v8)
        ksc = cache_l["k_scale"].at[phys, off].set(ks)
        vsc = cache_l["v_scale"].at[phys, off].set(vs)
        updates.update(k_scale=ksc, v_scale=vsc)
        k_scale_r = ksc[block_table].reshape(B, nblk * bs, -1)
        v_scale_r = vsc[block_table].reshape(B, nblk * bs, -1)
    else:
        kc = kc.at[phys, off].set(k)
        vc = vc.at[phys, off].set(v)
        k_scale_r = v_scale_r = None
    updates[kk] = kc
    updates[vk] = vc
    # gather each row's logical view of the pool: (B, nblk*bs, Hkv, hd)
    kb = kc[block_table].reshape(B, nblk * bs, kc.shape[2], kc.shape[3])
    vb = vc[block_table].reshape(B, nblk * bs, vc.shape[2], vc.shape[3])
    length = jnp.minimum(pos + 1, kv_ring)
    o = L.decode_attention_jnp(q, kb, vb, length, k_scale_r, v_scale_r)
    return L.linear(p["wo"], o.reshape(B, H * hd)), updates


def _layer_decode(lp, cache_l, x, pos, cfg, active=None, block_table=None,
                  kv_ring=None):
    fam = cfg.family
    new_cache = dict(cache_l)
    if fam == "ssm":
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        o, new_cache["mamba"] = S.mamba2_decode_step(
            lp["mamba"], h, cache_l["mamba"], cfg, active
        )
        return x + o, new_cache
    if fam == "audio":
        if block_table is not None:
            raise ValueError(
                "paged KV decode does not support the audio family (the "
                "cross-attention cache is per-row dense, not positional)"
            )
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        a, upd = _attn_decode(lp["attn"], h, cache_l, pos, cfg, 0, active)
        new_cache.update(upd)
        x = x + a
        hx = L.layernorm(lp["lnx"], x, cfg.norm_eps)
        B = x.shape[0]
        q = L.linear(lp["xattn"]["wq"], hx).reshape(B, cfg.num_heads, cfg.head_dim)
        xo = L.decode_attention_jnp(
            q, cache_l["xk"], cache_l["xv"],
            jnp.full((B,), cache_l["xk"].shape[1], jnp.int32),
        )
        x = x + L.linear(lp["xattn"]["wo"], xo.reshape(B, -1))
        h2 = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        return x + L.gelu_mlp(lp["mlp"], h2), new_cache
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if fam == "hybrid":
        a, upd = _attn_decode(
            lp["attn"], h, cache_l, pos, cfg, cfg.sliding_window, active,
            block_table=block_table, kv_ring=kv_ring,
        )
        new_cache.update(upd)
        m, new_cache["mamba"] = S.mamba2_decode_step(
            lp["mamba"], h, cache_l["mamba"], cfg, active
        )
        mix = (
            L.rmsnorm(lp["norm_a"], a, cfg.norm_eps).astype(jnp.float32)
            + L.rmsnorm(lp["norm_m"], m, cfg.norm_eps).astype(jnp.float32)
        ) * 0.5
        x = x + mix.astype(x.dtype)
    else:
        a, upd = _attn_decode(lp["attn"], h, cache_l, pos, cfg, 0, active,
                              block_table=block_table, kv_ring=kv_ring)
        new_cache.update(upd)
        x = x + a
    h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if fam == "moe":
        # decode uses dense-mode routing (few tokens; no capacity dispatch)
        y = L.moe_dense(lp["moe"], h2[:, None, :], cfg)[:, 0]
        return x + y, new_cache
    return x + L.swiglu(lp["mlp"], h2), new_cache


def decode_step(params: Params, cache: Params, tokens, pos, cfg, active=None,
                block_tables=None, kv_ring=None):
    """tokens: (B,) int32 newest tokens; pos: () or (B,) absolute positions
    (per-slot for continuous batching); active: optional (B,) write mask.

    With ``block_tables`` (B, max_blocks) int32 the cache must come from
    ``init_paged_cache`` and ``kv_ring`` (static int) is the logical ring
    capacity in tokens — the paged continuous-batching read/write path.

    Returns (logits (B, padded_vocab) f32, new cache).
    """
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(params["embed"], tokens)               # (B, d)

    def step(carry, xs):
        h = carry
        lp, cl = xs
        h2, ncl = _layer_decode(lp, cl, h, pos, cfg, active,
                                block_tables, kv_ring)
        return h2, ncl

    x, new_cache = jax.lax.scan(step, x, (params["layers"], cache))
    if cfg.family == "audio":
        x = L.layernorm(params["ln_f"], x, cfg.norm_eps)
    else:
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x).astype(jnp.float32)
    return logits, new_cache


def decode_chunk(params: Params, cache: Params, tokens, pos, cfg,
                 active=None, lengths=None, block_tables=None, kv_ring=None):
    """Token-chunk decode: ``tokens`` (B, C) int32, ``pos`` (B,) chunk-start
    absolute positions, ``lengths`` optional (B,) valid token counts within
    the chunk (ragged tails; default C), ``active`` optional (B,) slot mask.

    Runs the C per-token decode steps inside ONE traced call (a
    ``lax.scan`` over the chunk axis) — a length-S prefill costs
    O(ceil(S/C)) launches instead of O(S), while remaining step-for-step
    the same computation as C ``decode_step`` calls.  Positions past a
    slot's ``lengths`` are masked out of the cache write exactly like an
    inactive slot.

    Returns (logits (B, padded_vocab) f32 taken at each slot's LAST valid
    position, new cache); inactive or zero-length slots return zeros.
    """
    B, C = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    act = jnp.ones((B,), bool) if active is None else jnp.asarray(active)
    lengths = (
        jnp.full((B,), C, jnp.int32)
        if lengths is None
        else jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    )
    padded_vocab = params["embed"]["unembed"].shape[-1]
    last0 = jnp.zeros((B, padded_vocab), jnp.float32)

    def step(carry, xs):
        cache, last = carry
        toks_i, i = xs
        step_act = act & (i < lengths)
        logits, cache = decode_step(params, cache, toks_i, pos + i, cfg,
                                    step_act, block_tables, kv_ring)
        keep = (step_act & (i == lengths - 1))[:, None]
        return (cache, jnp.where(keep, logits, last)), None

    (cache, last), _ = jax.lax.scan(
        step, (cache, last0), (tokens.T, jnp.arange(C, dtype=jnp.int32))
    )
    return last, cache


def prefill_cross_attention(params: Params, frames, cfg, batch: int):
    """Whisper: run the encoder and precompute per-layer cross K/V."""
    enc = encode_audio(params, frames, cfg)            # (B, Se, d)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def per_layer(lp, _):
        k = L._split_heads(L.linear(lp["xattn"]["wk"], enc), Hkv, hd)
        v = L._split_heads(L.linear(lp["xattn"]["wv"], enc), Hkv, hd)
        return _, (k, v)

    _, (ks, vs) = jax.lax.scan(per_layer, None, params["layers"])
    return ks.astype(cfg.jax_dtype), vs.astype(cfg.jax_dtype)
